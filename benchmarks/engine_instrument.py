"""Engine instrumentation — GemmEvents driving the machine model.

Every Engine dispatch emits a GemmEvent (flops, bytes, tile, backend,
policy).  This benchmark feeds two *recorded* workloads into the calibrated
RedMulE machine model and cross-checks them against the hand-derived
analytic enumerations that predate the Engine:

* the TinyMLPerf AutoEncoder forward (paper §III-B) vs
  ``perf_model.autoencoder_gemms`` — recorded flops must equal analytic;
* the AE *train step* (``jax.value_and_grad``) vs the analytic fwd+bwd
  enumeration — the Engine ops' custom VJP makes the backward GEMMs
  (``matmul_dx`` / ``matmul_dw``) first-class events, so the recorded
  fwd:bwd ratio (1:2, i.e. train = 3x inference) and the paper's Fig 4c
  "bwd slower than fwd" cycle split both come straight from the trace;
* a reduced dense-LM forward vs ``perf_model.dense_forward_gemms``.

The point: the perf model consumes what actually ran, not a re-derivation.
"""

import jax
import jax.numpy as jnp

from benchmarks.common import Row, time_us
from repro import configs
from repro.core import autotune, engine
from repro.core import perf_model
from repro.core import precision as prec
from repro.data import SyntheticAE
from repro.models import autoencoder, transformer
from repro.roofline import analysis


def _linear_hotpath_row() -> Row:
    """Autotuned fused-linear hot path: tune the tile for one affine-layer
    shape (wall-clock on TPU, roofline cost model on CPU), then time
    ``engine.linear`` with the tuned tile on the default backend.  The
    chosen TileConfig rides in the derived column (and, via the resolved
    ``GemmSpec.tile``, on the GemmEvents run.py records)."""
    pol = prec.TPU_BF16
    M, N, K = 512, 2048, 512
    res = autotune.autotune_gemm(M, N, K, policy=pol, epilogue="gelu",
                                 with_bias=True)
    key = jax.random.PRNGKey(0)
    kx, kw, kb = jax.random.split(key, 3)
    x = jax.random.normal(kx, (M, N), pol.compute_dtype)
    w = jax.random.normal(kw, (N, K), pol.compute_dtype)
    b = jax.random.normal(kb, (K,), jnp.float32)

    fn = jax.jit(lambda xx, ww, bb: engine.linear(
        xx, ww, bb, activation="gelu", policy=pol, tile=res.tile))
    us = time_us(fn, x, w, b)
    t = res.tile
    return (
        f"engine/linear_fused_{M}x{N}x{K}", us,
        f"tile={t.bm}x{t.bn}x{t.bk} tuned={res.source} "
        f"tuned_us={res.us:.1f} candidates={res.n_candidates} "
        f"backend={engine.default_backend()}")


def _ae_train_bytes_row() -> Row:
    """One-pass vs two-pass backward HBM bytes on the AE train step.

    The same train trace is recorded against the fused-bwd-capable
    "interpret" backend (act'/db folded into the dX/dW kernels — ds never
    round-trips HBM) and the "xla" fallback (standalone ds multiply +
    separate bias-grad reduction, billed as linear_dact / linear_dbias
    pass events).  The derived column carries both backward byte totals;
    CI's bwd-perf-gates step pins them via
    benchmarks/baselines/train_bytes.json."""
    params = autoencoder.init_ae(jax.random.PRNGKey(0))
    B = 16
    x = jnp.asarray(SyntheticAE(batch=B).sample(0))

    def bwd_bytes(backend):
        with engine.instrument() as events:
            jax.eval_shape(
                lambda p: jax.value_and_grad(
                    lambda q: autoencoder.ae_loss(
                        q, x, policy=prec.PAPER_FP16, backend=backend)[0]
                )(p), params)
        return analysis.bytes_by_direction(events)

    fused = bwd_bytes("interpret")
    twop = bwd_bytes("xla")
    saved = int(twop["bwd"] - fused["bwd"])
    ok = fused["bwd"] < twop["bwd"]
    return (
        f"engine/ae_train_bytes_B{B}", 0.0,
        f"bwd_bytes_fused={int(fused['bwd'])} "
        f"bwd_bytes_two_pass={int(twop['bwd'])} saved={saved} "
        f"fwd_bytes={int(fused['fwd'])} "
        f"ds_roundtrip_eliminated={'OK' if ok else 'MISMATCH'}")


def _ae_train_fp8_row() -> Row:
    """Mixed-precision (FP8 storage) vs FP16 AE train-step GEMM bytes.

    The same train trace is recorded under ``mixed_fp8_e4m3`` (E4M3
    weights/activations, E5M2 grads, per-tensor scales, FP16 datapath —
    the mixed-precision RedMulE point) and under ``paper_fp16``, both on
    the "interpret" backend.  The per-operand byte accounting prices the
    FP8 streams at one byte per element, so ``engine_bytes`` drops
    strictly below the FP16 run at **identical** ``engine_flops`` (MACs
    are storage-width-invariant) — CI pins both totals against
    ``benchmarks/baselines/train_bytes.json``."""
    params = autoencoder.init_ae(jax.random.PRNGKey(0))
    B = 16
    x = jnp.asarray(SyntheticAE(batch=B).sample(0))

    def trace(policy):
        with engine.instrument() as events:
            jax.eval_shape(
                lambda p: jax.value_and_grad(
                    lambda q: autoencoder.ae_loss(
                        q, x, policy=policy, backend="interpret")[0]
                )(p), params)
        return events

    ev8 = trace(prec.MIXED_FP8_E4M3)
    ev16 = trace(prec.PAPER_FP16)
    b8 = perf_model.workload_hbm_bytes_from_events(ev8)
    b16 = perf_model.workload_hbm_bytes_from_events(ev16)
    f8, f16 = engine.total_flops(ev8), engine.total_flops(ev16)
    ok = b8["total"] < b16["total"] and f8 == f16
    return (
        "engine/ae_train_fp8", 0.0,
        f"engine_bytes_fp8={b8['total']} engine_bytes_fp16={b16['total']} "
        f"saved={b16['total'] - b8['total']} "
        f"fwd={b8['fwd']} bwd={b8['bwd']} engine_flops={int(f8)} "
        f"flops_match={'OK' if f8 == f16 else 'MISMATCH'} "
        f"bytes_drop_flops_dont={'OK' if ok else 'MISMATCH'}")


def _attn_flash_row() -> Row:
    """First-class flash attention: tuned sweep geometry + exact bill.

    ``autotune_attention`` picks (bq, bkv) for the shape and records it
    under the ``attnc`` sweep key; the dispatch below resolves that tile
    from the cache.  The derived column carries the causal vs dense flop
    bills (skipped KV blocks are free) and the kernel vs reference byte
    bills (the flash sweep never round-trips the S x T score tensor) —
    CI pins these via engine_flops.json / train_bytes.json."""
    B, H, S, D = 2, 4, 256, 64
    res = autotune.autotune_attention(S, S, D, policy=prec.FP32,
                                      backend="interpret", causal=True)
    key = jax.random.PRNGKey(5)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, H, S, D), jnp.float32)
    k = jax.random.normal(kk, (B, H, S, D), jnp.float32)
    v = jax.random.normal(kv, (B, H, S, D), jnp.float32)

    # the identity check bills a fixed bq=bkv=128 geometry (the
    # engine_flops.json pins) — the tuner may legitimately pick a
    # single-pair tile where causal has nothing to skip
    bq = bkv = 128

    def trace(causal, backend):
        with engine.instrument() as events:
            jax.eval_shape(lambda a, b, c: engine.attention(
                a, b, c, causal=causal, bq=bq, bkv=bkv, policy=prec.FP32,
                backend=backend), q, k, v)
        return events

    ev_c = trace(True, "interpret")
    ev_d = trace(False, "interpret")
    ev_r = trace(True, "xla")
    fc = int(engine.total_flops(ev_c))
    fd = int(engine.total_flops(ev_d))
    bk_ = int(sum(e.total_bytes for e in ev_c))
    br = int(sum(e.total_bytes for e in ev_r))
    pairs = autotune._attn_pairs(S, S, bq, bkv, causal=True)
    want = 2 * 2 * B * H * pairs * bq * bkv * D  # score + PV GEMMs
    ok = fc == want and fc < fd and bk_ < br
    return (
        "engine/attn_flash", 0.0,
        f"tuned_bq={res.tile.bm} tuned_bkv={res.tile.bn} "
        f"tuned_us={res.us:.1f} pairs={pairs} "
        f"flops_causal={fc} flops_dense={fd} bytes_kernel={bk_} "
        f"bytes_reference={br} bill_exact={'OK' if ok else 'MISMATCH'}")


def _attn_linear_row() -> Row:
    """Chunked linear attention (mLSTM/SSD state sweep): tuned chunk +
    the four per-chunk GEMM bills (intra score/PV, inter-chunk read,
    state update) — groups = number of chunks, state stores once."""
    B, H, S, dk, dv = 2, 4, 256, 32, 64
    res = autotune.autotune_attention(S, dk, dv, policy=prec.FP32,
                                      backend="interpret",
                                      kind="linear_attention")
    key = jax.random.PRNGKey(6)
    kq, kk, kv, kg = jax.random.split(key, 4)
    q = jax.random.normal(kq, (B, H, S, dk), jnp.float32)
    k = jax.random.normal(kk, (B, H, S, dk), jnp.float32)
    v = jax.random.normal(kv, (B, H, S, dv), jnp.float32)
    g = -jnp.abs(jax.random.normal(kg, (B, H, S), jnp.float32)) * 0.1
    with engine.instrument() as events:
        jax.eval_shape(lambda a, b, c, d: engine.linear_attention(
            a, b, c, d, backend="interpret"), q, k, v, g)
    c = res.tile.bm
    n = -(-S // c)
    got = int(engine.total_flops(events))
    want = 2 * B * H * n * c * (c * dk + c * dv + 2 * dk * dv)
    st = next(e for e in events
              if e.spec.op == "linear_attention_state")
    ok = got == want and st.bytes == B * H * dk * dv * 4
    return (
        "engine/attn_linear", 0.0,
        f"chunk={c} tuned_us={res.us:.1f} chunks={n} flops={got} "
        f"analytic_flops={want} state_bytes={st.bytes} "
        f"bill_exact={'OK' if ok else 'MISMATCH'}")


def run() -> list[Row]:
    rows: list[Row] = [_linear_hotpath_row(), _ae_train_bytes_row(),
                       _ae_train_fp8_row(), _attn_flash_row(),
                       _attn_linear_row()]
    m = perf_model.DEFAULT_MODEL

    # --- AE forward: recorded events vs the paper's analytic enumeration ---
    params = autoencoder.init_ae(jax.random.PRNGKey(0))
    B = 16
    x = jnp.asarray(SyntheticAE(batch=B).sample(0))
    with engine.instrument() as events:
        jax.eval_shape(
            lambda p, xx: autoencoder.ae_forward(p, xx, policy=prec.PAPER_FP16),
            params, x)
    got = engine.total_flops(events)
    # analytic fwd GEMMs use the transposed (out, in) x (in, B) convention;
    # macs (and so flops) are orientation-invariant
    want = perf_model.workload_flops(
        [(g, 1) for g in perf_model.autoencoder_gemms(B)["fwd"]])
    hw, sw = perf_model.workload_cycles_from_events(m, events)
    rows.append((
        f"engine/ae_fwd_B{B}", 0.0,
        f"event_flops={got} analytic_flops={want} "
        f"match={'OK' if got == want else 'MISMATCH'} "
        f"model_speedup={sw/hw:.2f}x"))

    # --- AE train step: fwd+bwd events vs the analytic enumeration ---
    with engine.instrument() as events:
        jax.eval_shape(
            lambda p, xx: jax.value_and_grad(
                lambda q: autoencoder.ae_loss(q, xx,
                                              policy=prec.PAPER_FP16)[0])(p),
            params, x)
    split = analysis.flops_by_direction(events)
    gs = perf_model.autoencoder_gemms(B)
    want_f = perf_model.workload_flops([(g, 1) for g in gs["fwd"]])
    want_b = perf_model.workload_flops([(g, 1) for g in gs["bwd"]])
    cyc = perf_model.workload_cycles_by_direction(m, events)
    ok = split["fwd"] == want_f and split["bwd"] == want_b
    rows.append((
        f"engine/ae_train_B{B}", 0.0,
        f"fwd_flops={int(split['fwd'])} bwd_flops={int(split['bwd'])} "
        f"analytic_fwd={want_f} analytic_bwd={want_b} "
        f"match={'OK' if ok else 'MISMATCH'} "
        f"fwd:bwd=1:{split['bwd']/split['fwd']:.2f} "
        f"model_bwd/fwd_cycles={cyc['bwd'][0]/cyc['fwd'][0]:.2f}x"))

    # --- dense LM forward: recorded events vs dense_forward_gemms ---
    cfg = configs.get_reduced("yi-9b")
    lm_params = transformer.init_params(jax.random.PRNGKey(1), cfg)
    Bl, S = 2, 64
    batch = {"inputs": jnp.zeros((Bl, S), jnp.int32)}
    with engine.instrument() as events:
        jax.eval_shape(lambda p, b: transformer.forward(p, cfg, b)[0],
                       lm_params, batch)
    got = engine.total_flops(events)
    want = perf_model.workload_flops(
        perf_model.dense_forward_gemms(cfg, Bl, S))
    rows.append((
        f"engine/lm_fwd_{cfg.name}", 0.0,
        f"event_flops={got} analytic_flops={want} "
        f"match={'OK' if got == want else 'MISMATCH'} "
        f"events={len(events)}"))
    return rows
