"""Engine instrumentation — GemmEvents driving the machine model.

Every Engine dispatch emits a GemmEvent (flops, bytes, tile, backend,
policy).  This benchmark feeds two *recorded* workloads into the calibrated
RedMulE machine model and cross-checks them against the hand-derived
analytic enumerations that predate the Engine:

* the TinyMLPerf AutoEncoder forward (paper §III-B) vs
  ``perf_model.autoencoder_gemms`` — recorded flops must equal analytic;
* a reduced dense-LM forward vs ``perf_model.dense_forward_gemms``.

The point: the perf model consumes what actually ran, not a re-derivation.
"""

import jax
import jax.numpy as jnp

from benchmarks.common import Row
from repro import configs
from repro.core import engine
from repro.core import perf_model
from repro.core import precision as prec
from repro.data import SyntheticAE
from repro.models import autoencoder, transformer


def run() -> list[Row]:
    rows: list[Row] = []
    m = perf_model.DEFAULT_MODEL

    # --- AE forward: recorded events vs the paper's analytic enumeration ---
    params = autoencoder.init_ae(jax.random.PRNGKey(0))
    B = 16
    x = jnp.asarray(SyntheticAE(batch=B).sample(0))
    with engine.instrument() as events:
        jax.eval_shape(
            lambda p, xx: autoencoder.ae_forward(p, xx, policy=prec.PAPER_FP16),
            params, x)
    got = engine.total_flops(events)
    # analytic fwd GEMMs use the transposed (out, in) x (in, B) convention;
    # macs (and so flops) are orientation-invariant
    want = perf_model.workload_flops(
        [(g, 1) for g in perf_model.autoencoder_gemms(B)["fwd"]])
    hw, sw = perf_model.workload_cycles_from_events(m, events)
    rows.append((
        f"engine/ae_fwd_B{B}", 0.0,
        f"event_flops={got} analytic_flops={want} "
        f"match={'OK' if got == want else 'MISMATCH'} "
        f"model_speedup={sw/hw:.2f}x"))

    # --- dense LM forward: recorded events vs dense_forward_gemms ---
    cfg = configs.get_reduced("yi-9b")
    lm_params = transformer.init_params(jax.random.PRNGKey(1), cfg)
    Bl, S = 2, 64
    batch = {"inputs": jnp.zeros((Bl, S), jnp.int32)}
    with engine.instrument() as events:
        jax.eval_shape(lambda p, b: transformer.forward(p, cfg, b)[0],
                       lm_params, batch)
    got = engine.total_flops(events)
    want = perf_model.workload_flops(
        perf_model.dense_forward_gemms(cfg, Bl, S))
    rows.append((
        f"engine/lm_fwd_{cfg.name}", 0.0,
        f"event_flops={got} analytic_flops={want} "
        f"match={'OK' if got == want else 'MISMATCH'} "
        f"events={len(events)}"))
    return rows
