"""Serving loadgen sweep (beyond-paper §Serving): continuous batching
over the FP8 KV cache, p50/p99 TTFT and tokens/s vs Poisson offered load.

One dense (yi-9b reduced) and one MoE (deepseek-moe-16b reduced) arch,
two offered loads each — the ``serve/*`` rows land in BENCH_engine.json
so the serving latency/throughput trajectory is diffable across commits
(absolute numbers are host-CPU emulation timings; the load-vs-latency
*shape* and the batch-fill ratios are the signal).
"""

import dataclasses

import jax

from repro import configs
from repro.models import transformer
from repro.serving import LoadConfig, SchedulerConfig, bench_rows

ARCHS = ("yi-9b", "deepseek-moe-16b")
RATES = (0.25, 1.0)


def run():
    rows = []
    for arch in ARCHS:
        # FP8 end to end: E4M3 KV storage AND MIXED_FP8_E4M3 decode GEMMs
        cfg = dataclasses.replace(
            configs.get_reduced(arch), policy_name="mixed_fp8_e4m3")
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        scfg = SchedulerConfig(
            n_slots=4, max_len=16, storage_dtype="float8_e4m3fn")
        lc = LoadConfig(rate=1.0, n_requests=6, prompt_len=6, gen_len=6,
                        seed=0)
        rows += bench_rows(params, cfg, scfg, arch, RATES, lc)
    return rows
