"""Serving loadgen sweep (beyond-paper §Serving): continuous batching
over the FP8 KV cache, p50/p99 TTFT and tokens/s vs Poisson offered load.

One dense (yi-9b reduced) and one MoE (deepseek-moe-16b reduced) arch,
two offered loads each — the ``serve/*`` rows land in BENCH_engine.json
so the serving latency/throughput trajectory is diffable across commits
(absolute numbers are host-CPU emulation timings; the load-vs-latency
*shape* and the batch-fill ratios are the signal).

The ``serve/*/slo_*`` rows replay the pinned SLO scenario from
``benchmarks/baselines/serve_slo.json`` — deadlines, bounded admission,
and one injected serving fault (``nan_logits`` / ``kv_corrupt``) per
run — so the serve-goodput cost of recovery is diffable too (the gate
itself lives in tests/test_serve_resilience.py, the
``serve-resilience-gates`` CI job).
"""

import dataclasses
import json
import os

import jax

from repro import configs
from repro.models import transformer
from repro.runtime.fault_tolerance import FailureInjector
from repro.serving import LoadConfig, SchedulerConfig, bench_rows, slo_rows

ARCHS = ("yi-9b", "deepseek-moe-16b")
RATES = (0.25, 1.0)
SLO_BASELINE = os.path.join(os.path.dirname(__file__), "baselines",
                            "serve_slo.json")


def _slo_scenario_rows():
    with open(SLO_BASELINE) as f:
        sc = json.load(f)["scenario"]
    cfg = configs.get_reduced(sc["arch"])
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    scfg = SchedulerConfig(
        n_slots=sc["n_slots"], max_len=sc["max_len"],
        storage_dtype=sc["storage_dtype"], max_queue=sc["max_queue"],
        audit_every=sc["audit_every"])
    lc = LoadConfig(
        rate=sc["rate"], n_requests=sc["n_requests"],
        prompt_len=sc["prompt_len"], gen_len=sc["gen_len"], seed=sc["seed"],
        deadline_ticks=sc["deadline_ticks"], max_retries=sc["max_retries"])
    rows = []
    for mode in (None, "nan_logits", "kv_corrupt"):
        injector = None if mode is None else FailureInjector(
            fail_at_step=sc["inject_step"], mode=mode)
        r, _ = slo_rows(params, cfg, scfg, sc["arch"], lc, injector=injector,
                        tag=f"slo_{mode}" if mode else "slo")
        rows += r
    return rows


def run():
    rows = []
    for arch in ARCHS:
        # FP8 end to end: E4M3 KV storage AND MIXED_FP8_E4M3 decode GEMMs
        cfg = dataclasses.replace(
            configs.get_reduced(arch), policy_name="mixed_fp8_e4m3")
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        scfg = SchedulerConfig(
            n_slots=4, max_len=16, storage_dtype="float8_e4m3fn")
        lc = LoadConfig(rate=1.0, n_requests=6, prompt_len=6, gen_len=6,
                        seed=0)
        rows += bench_rows(params, cfg, scfg, arch, RATES, lc)
    rows += _slo_scenario_rows()
    return rows
