"""Fig 4c/4d — TinyMLPerf AutoEncoder fwd/bwd speedups and the batching
effect.

The model-derived speedups reproduce the paper (2.6x @ B=1, bwd > fwd,
~16x HW throughput gain and 24.4x @ B=16); the measured column times the
REAL AE fwd/bwd on this host via the framework (functional end-to-end
reproduction of the use case, pure FP16).
"""

import jax
import jax.numpy as jnp

from benchmarks.common import Row, time_us
from repro.core import precision as prec
from repro.core.perf_model import DEFAULT_MODEL, autoencoder_report
from repro.data import SyntheticAE
from repro.models import autoencoder


def run() -> list[Row]:
    m = DEFAULT_MODEL
    rows: list[Row] = []
    params = autoencoder.init_ae(jax.random.PRNGKey(0))

    fwd = jax.jit(lambda p, x: autoencoder.ae_forward(p, x, policy=prec.PAPER_FP16))
    bwd = jax.jit(jax.grad(
        lambda p, x: autoencoder.ae_loss(p, x, policy=prec.PAPER_FP16)[0]))

    for B in (1, 16):
        x = jnp.asarray(SyntheticAE(batch=B).sample(0))
        us_f = time_us(fwd, params, x)
        us_b = time_us(bwd, params, x)
        r = autoencoder_report(m, B)
        rows.append((
            f"fig4c/ae_fwd_B{B}", us_f,
            f"model_speedup_fwd={r['speedup_fwd']:.2f}x"))
        rows.append((
            f"fig4c/ae_bwd_B{B}", us_b,
            f"model_speedup_bwd={r['speedup_bwd']:.2f}x"))
        rows.append((
            f"fig4cd/ae_total_B{B}", us_f + us_b,
            f"model_speedup={r['speedup']:.2f}x paper={'2.6x' if B == 1 else '24.4x'} "
            f"hw_macs_per_cyc={r['hw_macs_per_cycle']:.2f} "
            f"act_footprint={r['footprint_kb']:.0f}kB"))
    r1 = autoencoder_report(m, 1)
    r16 = autoencoder_report(m, 16)
    rows.append((
        "fig4d/batching_throughput_gain", 0.0,
        f"model={r16['hw_macs_per_cycle']/r1['hw_macs_per_cycle']:.1f}x "
        f"paper=~16x"))
    return rows
