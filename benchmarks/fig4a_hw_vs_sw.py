"""Fig 4a — HW vs SW computational performance vs the 32-MAC/cycle ideal.

Model-derived HW cycles vs SW cycles across sizes; asserts-by-construction
that the large-size fraction approaches 98.8% of ideal and the speedup
approaches 22x.
"""

from benchmarks.common import Row
from repro.core.perf_model import DEFAULT_MODEL, GEMM

SIZES = [32, 64, 96, 128, 192, 256, 304, 384, 512, 1024]


def run() -> list[Row]:
    m = DEFAULT_MODEL
    rows: list[Row] = []
    for s in SIZES:
        g = GEMM(s, s, s)
        hw = m.hw_cycles(g)
        sw = m.sw_cycles(g)
        rows.append((
            f"fig4a/size_{s}", 0.0,
            f"hw={hw}cyc sw={sw:.0f}cyc speedup={sw/hw:.1f}x "
            f"ideal_frac={m.utilization(g)*100:.1f}%"))
    return rows
