"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Measured numbers are the host's
software-counterpart timings; derived numbers come from the calibrated
RedMulE machine model (Table I / Figs 3-4) and from the dry-run roofline
artifacts (beyond-paper §Roofline).
"""

from benchmarks import (engine_instrument, fig3_energy_throughput,
                        fig4a_hw_vs_sw, fig4b_area_sweep, fig4cd_autoencoder,
                        roofline_report, table1_soa)
from benchmarks.common import emit


def main() -> None:
    print("name,us_per_call,derived")
    for mod in (table1_soa, fig3_energy_throughput, fig4a_hw_vs_sw,
                fig4b_area_sweep, fig4cd_autoencoder, engine_instrument,
                roofline_report):
        emit(mod.run())


if __name__ == "__main__":
    main()
