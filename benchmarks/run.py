"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and writes a machine-readable
``BENCH_engine.json`` next to it: one record per CSV row, annotated with
the Engine instrumentation observed while the module ran (GEMM flops and
the resolved TileConfigs), so the perf trajectory of the hot path is
diffable across commits.  Measured numbers are the host's software-
counterpart timings; derived numbers come from the calibrated RedMulE
machine model (Table I / Figs 3-4) and from the dry-run roofline artifacts
(beyond-paper §Roofline).

CLI:

    python -m benchmarks.run                  # everything
    python -m benchmarks.run --only engine    # modules whose name contains
                                              # "engine" (repeatable; CI's
                                              # cheap subset)
    python -m benchmarks.run --json out.json  # alternate JSON path ("" off)
"""

import argparse
import json
from typing import List, Optional

from benchmarks import (engine_instrument, fig3_energy_throughput,
                        fig4a_hw_vs_sw, fig4b_area_sweep, fig4cd_autoencoder,
                        ft_goodput, roofline_report, serve_loadgen, table1_soa)
from benchmarks.common import emit
from repro.core import autotune, engine
from repro.roofline import analysis

MODULES = [
    ("table1_soa", table1_soa),
    ("fig3_energy_throughput", fig3_energy_throughput),
    ("fig4a_hw_vs_sw", fig4a_hw_vs_sw),
    ("fig4b_area_sweep", fig4b_area_sweep),
    ("fig4cd_autoencoder", fig4cd_autoencoder),
    ("engine_instrument", engine_instrument),
    ("roofline_report", roofline_report),
    ("serve_loadgen", serve_loadgen),
    ("ft_goodput", ft_goodput),
]

DEFAULT_JSON = "BENCH_engine.json"


def _select(only: Optional[List[str]]):
    if not only:
        return MODULES
    chosen = [(n, m) for n, m in MODULES
              if any(pat in n for pat in only)]
    if not chosen:
        names = ", ".join(n for n, _ in MODULES)
        raise SystemExit(f"--only matched no benchmark module; known: {names}")
    return chosen


def run_benchmarks(only: Optional[List[str]] = None) -> List[dict]:
    """Run the selected modules, print the CSV, return the JSON records."""
    records: List[dict] = []
    print("name,us_per_call,derived")
    for mod_name, mod in _select(only):
        with engine.instrument() as events:
            rows = mod.run()
        emit(rows)
        flops = engine.total_flops(events)
        byts = engine.total_bytes(events)
        split = analysis.flops_by_direction(events)
        bsplit = analysis.bytes_by_direction(events)
        tiles = sorted({(ev.spec.tile.bm, ev.spec.tile.bn, ev.spec.tile.bk)
                        for ev in events if ev.spec.tile is not None})
        for name, us, derived in rows:
            records.append({
                "name": name,
                "us_per_call": round(float(us), 3),
                "derived": derived,
                "module": mod_name,
                "engine_flops": int(flops),
                # fwd/bwd split: the Engine's custom-VJP backward GEMMs
                # (matmul_dx / matmul_dw) are instrumented like any other
                # dispatch, so train-shaped modules show bwd ~ 2x fwd
                "engine_flops_fwd": int(split["fwd"]),
                "engine_flops_bwd": int(split["bwd"]),
                # byte split: backward bytes carry the epilogue traffic
                # (fused derivative streams / db output, or the two-pass
                # *_dact / *_dbias round-trips) — the bwd-perf-gates CI
                # step pins these against benchmarks/baselines/
                # train_bytes.json
                "engine_bytes": int(byts),
                "engine_bytes_fwd": int(bsplit["fwd"]),
                "engine_bytes_bwd": int(bsplit["bwd"]),
                "tiles": [list(t) for t in tiles],
            })
    return records


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--only", action="append", metavar="NAME",
        help="run only modules whose name contains NAME (repeatable)")
    ap.add_argument(
        "--json", default=DEFAULT_JSON, metavar="PATH",
        help=f"machine-readable output path (default {DEFAULT_JSON}; "
             "'' disables)")
    args = ap.parse_args(argv)
    records = run_benchmarks(args.only)
    if args.json:
        with open(args.json, "w") as fh:
            # autotune_cache: in-process LRU observability (hit/miss/evict
            # counters over the whole run) — the CI autotuner smoke asserts
            # the cold-miss -> warm-hit transition shows up here
            json.dump({"benchmarks": records,
                       "autotune_cache": autotune.cache_stats()},
                      fh, indent=2)


if __name__ == "__main__":
    main()
