"""Table I — state-of-the-art comparison row for PULP+RedMulE.

Derived columns reproduce the published row from the machine model and
report the relative error; the us_per_call column measures the CPU jnp GEMM
(the software-counterpart role).
"""

import jax
import jax.numpy as jnp

from benchmarks.common import Row, time_us
from repro.core.perf_model import DEFAULT_MODEL, GEMM, TABLE1_PUBLISHED


def run() -> list[Row]:
    m = DEFAULT_MODEL
    g = GEMM(1024, 1024, 1024)
    x = jnp.ones((g.M, g.N), jnp.float16)
    w = jnp.ones((g.N, g.K), jnp.float16)
    f = jax.jit(lambda a, b: (a @ b).astype(jnp.float16))
    us = time_us(f, x, w)

    pub_eff = TABLE1_PUBLISHED["pulp_redmule_22nm_peak_eff"]
    pub_perf = TABLE1_PUBLISHED["pulp_redmule_22nm_peak_perf"]
    rows: list[Row] = []

    def row(name, model_val, published, unit):
        err = abs(model_val - published) / published * 100
        rows.append((f"table1/{name}", us,
                     f"model={model_val:.3g}{unit} paper={published}{unit} "
                     f"err={err:.1f}%"))

    row("perf_gops_665mhz", m.gflops(g, m.freq_peak_perf_mhz),
        pub_perf["perf_gops"], "GOPS")
    row("perf_gops_476mhz", m.gflops(g, m.freq_peak_eff_mhz),
        pub_eff["perf_gops"], "GOPS")
    row("eff_gops_per_w_065v", m.gflops_per_watt(g), pub_eff["gops_per_w"], "")
    row("eff_gops_per_w_080v", m.gflops_per_watt(g, peak_perf=True),
        pub_perf["gops_per_w"], "")
    row("area_mm2", m.area_mm2(), 0.07, "mm2")
    rows.append(("table1/macs_per_cycle", us,
                 f"model={m.hw_macs_per_cycle(GEMM(304, 304, 304)):.1f} "
                 f"paper=31.6 (98.8% util)"))
    rows.append(("table1/speedup_vs_8core_sw", us,
                 f"model={m.speedup(g):.1f}x paper=22x"))
    rows.append(("table1/eff_gain_vs_sw", us,
                 f"model={m.efficiency_gain_vs_sw(g):.2f}x paper=4.65x"))
    return rows
